//! Client partition protocols from the paper (§4.1):
//!
//! * **Mixed-CIFAR** — one 10-class family; the classes are divided into 5
//!   subsets of 2 distinct classes and every client gets one subset
//!   (low, consistent inter-client heterogeneity). Global head: 10.
//! * **Mixed-NonIID** — five families, one per client; labels live in a
//!   disjoint global space of 5 x 10 = 50 classes (high, *variable*
//!   pairwise heterogeneity: the mnist-like/fmnist-like pair is close,
//!   cifar100-like is far from everything).
//!
//! Supports client dataset-size imbalance (`imbalance` skews sizes
//! geometrically) so FedNova's normalized averaging has real work to do.
//!
//! Shards are generated **lazily**: a [`Partition`] only stores the
//! per-client sizes (cheap) up front, and materializes a client's
//! [`ClientData`] on first touch. Each shard is a pure function of
//! (dataset kind, client id, seed) — materialization order, caching, and
//! eviction can never change values. Under per-round sampling the driver
//! points the cache at the active participant set
//! ([`Partition::retain`]), so at `--clients 1000, p=0.05` only ~50
//! shards are resident; out-of-sample reads (per-round evaluation) hand
//! back transient shards that drop after use.

use std::sync::{Arc, RwLock};

use anyhow::{ensure, Result};

use crate::data::rng::Rng;
use crate::data::synthetic::{Family, SyntheticDataset, PIXELS};

pub const CLASSES_PER_FAMILY: usize = 10;

/// Which partition protocol to run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DatasetKind {
    MixedCifar,
    MixedNonIid,
}

impl std::str::FromStr for DatasetKind {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self> {
        match s {
            "mixed-cifar" => Ok(DatasetKind::MixedCifar),
            "mixed-noniid" => Ok(DatasetKind::MixedNonIid),
            other => anyhow::bail!("unknown dataset `{other}` (mixed-cifar | mixed-noniid)"),
        }
    }
}

impl DatasetKind {
    pub fn name(&self) -> &'static str {
        match self {
            DatasetKind::MixedCifar => "mixed-cifar",
            DatasetKind::MixedNonIid => "mixed-noniid",
        }
    }

    /// Size of the global label space (classifier head).
    pub fn num_classes(&self) -> usize {
        match self {
            DatasetKind::MixedCifar => CLASSES_PER_FAMILY,
            DatasetKind::MixedNonIid => CLASSES_PER_FAMILY * Family::ALL.len(),
        }
    }

    /// Artifact tag prefix for this label-space size (`c10` / `c50`).
    pub fn tag(&self) -> &'static str {
        match self {
            DatasetKind::MixedCifar => "c10",
            DatasetKind::MixedNonIid => "c50",
        }
    }
}

/// Materialized train/test split for one client.
pub struct ClientData {
    pub id: usize,
    pub family: Family,
    /// global-space class labels this client can emit
    pub classes: Vec<usize>,
    pub train_x: Vec<f32>,
    pub train_y: Vec<f32>,
    pub test_x: Vec<f32>,
    pub test_y: Vec<f32>,
}

impl ClientData {
    pub fn train_len(&self) -> usize {
        self.train_y.len()
    }

    pub fn test_len(&self) -> usize {
        self.test_y.len()
    }
}

/// Per-client train-set sizes under a geometric imbalance factor.
/// `imbalance = 1.0` gives equal sizes; `2.0` makes each client twice the
/// previous one's size (normalized to keep the total close to n*base).
pub fn imbalanced_sizes(n_clients: usize, base: usize, imbalance: f64) -> Vec<usize> {
    if (imbalance - 1.0).abs() < 1e-9 {
        return vec![base; n_clients];
    }
    let weights: Vec<f64> = (0..n_clients).map(|i| imbalance.powi(i as i32)).collect();
    let total: f64 = weights.iter().sum();
    weights
        .iter()
        .map(|w| ((w / total) * (base * n_clients) as f64).round().max(32.0) as usize)
        .collect()
}

/// The experiment's client shards, generated lazily on first touch.
///
/// Residency follows the driver's sampling discipline: ids inside the
/// `keep` set ([`Partition::retain`]; everyone by default) are cached on
/// materialization, everything else is handed out as a transient
/// `Arc<ClientData>` that frees itself when the caller drops it. Shards
/// are pure functions of (kind, id, seed), so a regenerated shard is
/// bit-identical to the evicted one.
pub struct Partition {
    kind: DatasetKind,
    /// per-client train-set sizes (cheap; known without materializing)
    sizes: Vec<usize>,
    test_per_client: usize,
    seed: u64,
    keep: Vec<bool>,
    slots: Vec<RwLock<Option<Arc<ClientData>>>>,
}

impl Partition {
    pub fn new(
        kind: DatasetKind,
        n_clients: usize,
        train_per_client: usize,
        test_per_client: usize,
        imbalance: f64,
        seed: u64,
    ) -> Result<Self> {
        ensure!(n_clients > 0, "need at least one client");
        Ok(Self {
            kind,
            sizes: imbalanced_sizes(n_clients, train_per_client, imbalance),
            test_per_client,
            seed,
            keep: vec![true; n_clients],
            slots: (0..n_clients).map(|_| RwLock::new(None)).collect(),
        })
    }

    pub fn len(&self) -> usize {
        self.slots.len()
    }

    pub fn is_empty(&self) -> bool {
        self.slots.is_empty()
    }

    /// The client's train-set size, without materializing the shard
    /// (aggregation weights need only this).
    pub fn train_len(&self, id: usize) -> usize {
        self.sizes[id]
    }

    /// One client's shard, materializing on first touch. Cached only for
    /// ids inside the current keep set; other reads are transient.
    pub fn get(&self, id: usize) -> Arc<ClientData> {
        if let Some(c) = self.slots[id].read().expect("partition lock").as_ref() {
            return c.clone();
        }
        let data = Arc::new(self.generate(id));
        if self.keep[id] {
            let mut w = self.slots[id].write().expect("partition lock");
            if let Some(c) = w.as_ref() {
                // another worker materialized concurrently — same bits
                return c.clone();
            }
            *w = Some(data.clone());
        }
        data
    }

    /// Test-split-only read for evaluation sweeps. Cached shards come
    /// back whole; an out-of-cache id generates **only** its test split
    /// (train vectors left empty — train and test draw from independent
    /// sample-index ranges, so the test bits are identical to the full
    /// shard's). Never caches: at `--clients 1000, p=0.05` the per-round
    /// eval sweep skips ~2/3 of the generation work (train synthesis +
    /// shuffle) for the ~950 out-of-sample clients.
    pub fn get_for_eval(&self, id: usize) -> Arc<ClientData> {
        if let Some(c) = self.slots[id].read().expect("partition lock").as_ref() {
            return c.clone();
        }
        if self.keep[id] {
            // resident set: materialize and cache the full shard
            return self.get(id);
        }
        Arc::new(self.generate_sized(id, 0))
    }

    /// Point the cache at `keep` (ascending ids): cached shards outside
    /// the set are dropped, and future out-of-set reads stay transient.
    /// The driver calls this with the round's participant set whenever
    /// per-round sampling is active, mirroring the [`ClientStateStore`]
    /// residency discipline.
    ///
    /// [`ClientStateStore`]: crate::driver::ClientStateStore
    pub fn retain(&mut self, keep: &[usize]) {
        for (i, k) in self.keep.iter_mut().enumerate() {
            *k = keep.binary_search(&i).is_ok();
        }
        for (i, slot) in self.slots.iter_mut().enumerate() {
            if !self.keep[i] {
                *slot.get_mut().expect("partition lock") = None;
            }
        }
    }

    /// Ids whose shards are currently resident (tests/introspection).
    pub fn materialized_ids(&self) -> Vec<usize> {
        self.slots
            .iter()
            .enumerate()
            .filter(|(_, s)| s.read().expect("partition lock").is_some())
            .map(|(i, _)| i)
            .collect()
    }

    pub fn materialized_count(&self) -> usize {
        self.slots
            .iter()
            .filter(|s| s.read().expect("partition lock").is_some())
            .count()
    }

    /// Generate client `id`'s shard — a pure function of
    /// (kind, id, seed); bit-identical no matter when or how often it
    /// runs.
    fn generate(&self, id: usize) -> ClientData {
        self.generate_sized(id, self.sizes[id])
    }

    /// `generate` with an explicit train-set size: `0` skips train
    /// synthesis entirely (test generation uses an independent index
    /// range, so its bits do not depend on the train size).
    fn generate_sized(&self, id: usize, n_train: usize) -> ClientData {
        match self.kind {
            DatasetKind::MixedCifar => {
                // one family, 5 fixed 2-class shards assigned round-robin
                let ds =
                    SyntheticDataset::new(Family::Cifar10Like, CLASSES_PER_FAMILY, self.seed);
                let shard = id % (CLASSES_PER_FAMILY / 2);
                let classes = vec![2 * shard, 2 * shard + 1];
                materialize(
                    &ds, id, Family::Cifar10Like, &classes, 0, n_train,
                    self.test_per_client, self.seed,
                )
            }
            DatasetKind::MixedNonIid => {
                let family = Family::ALL[id % Family::ALL.len()];
                let ds = SyntheticDataset::new(family, CLASSES_PER_FAMILY, self.seed);
                let classes: Vec<usize> = (0..CLASSES_PER_FAMILY).collect();
                let offset = (id % Family::ALL.len()) * CLASSES_PER_FAMILY;
                materialize(
                    &ds, id, family, &classes, offset, n_train,
                    self.test_per_client, self.seed,
                )
            }
        }
    }
}

/// Build the partition for an experiment (shards generate lazily on
/// first touch — see [`Partition`]).
pub fn build_partition(
    kind: DatasetKind,
    n_clients: usize,
    train_per_client: usize,
    test_per_client: usize,
    imbalance: f64,
    seed: u64,
) -> Result<Partition> {
    Partition::new(kind, n_clients, train_per_client, test_per_client, imbalance, seed)
}

fn materialize(
    ds: &SyntheticDataset,
    id: usize,
    family: Family,
    classes: &[usize],
    label_offset: usize,
    n_train: usize,
    n_test: usize,
    seed: u64,
) -> ClientData {
    // distinct index ranges per client and per split => no duplicated samples
    let base = (id as u64) << 40;
    let (train_x, train_y) = ds.generate(classes, n_train, label_offset, base);
    let (test_x, test_y) = ds.generate(classes, n_test, label_offset, base + (1 << 30));
    // shuffle train set deterministically so round-robin class order does
    // not leak into batch composition
    let mut rng = Rng::new(seed).derive("partition-shuffle", id as u64);
    let perm = rng.permutation(n_train);
    let mut sx = vec![0.0f32; train_x.len()];
    let mut sy = vec![0.0f32; train_y.len()];
    for (dst, &src) in perm.iter().enumerate() {
        sx[dst * PIXELS..(dst + 1) * PIXELS]
            .copy_from_slice(&train_x[src * PIXELS..(src + 1) * PIXELS]);
        sy[dst] = train_y[src];
    }
    ClientData {
        id,
        family,
        classes: classes.iter().map(|c| c + label_offset).collect(),
        train_x: sx,
        train_y: sy,
        test_x,
        test_y,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mixed_cifar_shards_are_disjoint_pairs() {
        let parts = build_partition(DatasetKind::MixedCifar, 5, 64, 32, 1.0, 3).unwrap();
        let mut all: Vec<usize> = (0..5).flat_map(|i| parts.get(i).classes.clone()).collect();
        all.sort_unstable();
        assert_eq!(all, (0..10).collect::<Vec<_>>());
        for i in 0..5 {
            let c = parts.get(i);
            assert_eq!(c.classes.len(), 2);
            for &y in &c.train_y {
                assert!(c.classes.contains(&(y as usize)));
            }
        }
    }

    #[test]
    fn mixed_noniid_label_spaces_disjoint() {
        let parts = build_partition(DatasetKind::MixedNonIid, 5, 64, 32, 1.0, 3).unwrap();
        for i in 0..5 {
            let c = parts.get(i);
            assert_eq!(c.family, Family::ALL[i]);
            for &y in &c.train_y {
                let y = y as usize;
                assert!(y >= i * 10 && y < (i + 1) * 10);
            }
        }
    }

    #[test]
    fn sizes_and_determinism() {
        let a = build_partition(DatasetKind::MixedCifar, 3, 100, 40, 1.0, 9).unwrap();
        let b = build_partition(DatasetKind::MixedCifar, 3, 100, 40, 1.0, 9).unwrap();
        assert_eq!(a.get(0).train_len(), 100);
        assert_eq!(a.train_len(0), 100, "size known without materializing");
        assert_eq!(a.get(0).test_len(), 40);
        // materialization order must not matter: touch b back-to-front
        let b2 = b.get(2).train_y.clone();
        let b1 = b.get(1).train_x.clone();
        assert_eq!(a.get(1).train_x, b1);
        assert_eq!(a.get(2).train_y, b2);
    }

    #[test]
    fn imbalance_skews_sizes() {
        let sizes = imbalanced_sizes(4, 100, 2.0);
        assert!(sizes[3] > sizes[0] * 4);
        assert_eq!(imbalanced_sizes(4, 100, 1.0), vec![100; 4]);
    }

    #[test]
    fn train_test_disjoint() {
        let parts = build_partition(DatasetKind::MixedCifar, 1, 16, 16, 1.0, 5).unwrap();
        let c = parts.get(0);
        // same class list, but distinct sample index ranges => images differ
        assert_ne!(&c.train_x[..PIXELS], &c.test_x[..PIXELS]);
    }

    #[test]
    fn only_sampled_clients_shards_materialize_at_scale() {
        // the ROADMAP scale point: 1000 clients, p = 0.05 — per-round
        // residency must track the ~50-client sample, not the fleet.
        // Construction is cheap because nothing materializes up front.
        let mut part =
            Partition::new(DatasetKind::MixedCifar, 1000, 64, 32, 1.0, 7).unwrap();
        assert_eq!(part.len(), 1000);
        assert_eq!(part.materialized_count(), 0, "construction generates nothing");
        assert_eq!(part.train_len(999), 64, "sizes known without data");

        let mut rng = Rng::new(7);
        for round in 0..4 {
            // a seeded 5% sample, like SampledSync would draw
            let mut sample = rng.derive("test-sample", round).permutation(1000);
            sample.truncate(50);
            sample.sort_unstable();
            part.retain(&sample);
            for &i in &sample {
                let shard = part.get(i);
                assert_eq!(shard.id, i);
                assert_eq!(shard.train_len(), 64);
            }
            assert_eq!(
                part.materialized_ids(),
                sample,
                "round {round}: exactly the sampled shards are resident"
            );
        }

        // an out-of-sample read (eval sweep) is transient: it must not
        // grow the resident set
        let resident_before = part.materialized_count();
        let outside = (0..1000usize)
            .find(|i| part.materialized_ids().binary_search(i).is_err())
            .unwrap();
        let transient = part.get(outside);
        assert_eq!(transient.id, outside);
        assert_eq!(part.materialized_count(), resident_before);
    }

    #[test]
    fn get_for_eval_skips_train_synthesis_without_changing_test_bits() {
        let mut part = Partition::new(DatasetKind::MixedCifar, 8, 64, 32, 1.0, 13).unwrap();
        part.retain(&[2]);
        // out-of-sample: test split identical to the full shard's, train
        // skipped, nothing cached
        let full = Partition::new(DatasetKind::MixedCifar, 8, 64, 32, 1.0, 13)
            .unwrap()
            .get(5);
        let eval_view = part.get_for_eval(5);
        assert_eq!(eval_view.test_x, full.test_x, "test bits independent of train");
        assert_eq!(eval_view.test_y, full.test_y);
        assert_eq!(eval_view.train_len(), 0, "train synthesis skipped");
        assert!(part.materialized_ids().is_empty(), "eval reads never cache");
        // resident: the full cached shard comes back
        let resident = part.get(2);
        assert_eq!(resident.train_len(), 64);
        let resident_eval = part.get_for_eval(2);
        assert_eq!(resident_eval.train_len(), 64, "cached shard returned whole");
        assert_eq!(part.materialized_ids(), vec![2]);
    }

    #[test]
    fn eviction_and_regeneration_are_value_stable() {
        let mut part = Partition::new(DatasetKind::MixedNonIid, 6, 64, 32, 1.3, 11).unwrap();
        let first = part.get(4);
        let (x0, y0) = (first.train_x.clone(), first.train_y.clone());
        drop(first);
        part.retain(&[0, 1]); // evicts 4's cached shard (0/1 were never touched)
        assert!(part.materialized_ids().is_empty());
        let again = part.get(4); // transient regeneration
        assert_eq!(again.train_x, x0, "regenerated shard is bit-identical");
        assert_eq!(again.train_y, y0);
        part.retain(&[4]);
        let cached = part.get(4);
        assert_eq!(cached.train_x, x0);
        assert_eq!(part.materialized_ids(), vec![4]);
    }
}
