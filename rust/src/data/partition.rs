//! Client partition protocols from the paper (§4.1):
//!
//! * **Mixed-CIFAR** — one 10-class family; the classes are divided into 5
//!   subsets of 2 distinct classes and every client gets one subset
//!   (low, consistent inter-client heterogeneity). Global head: 10.
//! * **Mixed-NonIID** — five families, one per client; labels live in a
//!   disjoint global space of 5 x 10 = 50 classes (high, *variable*
//!   pairwise heterogeneity: the mnist-like/fmnist-like pair is close,
//!   cifar100-like is far from everything).
//!
//! Supports client dataset-size imbalance (`imbalance` skews sizes
//! geometrically) so FedNova's normalized averaging has real work to do.
//!
//! Shards are generated **lazily**: a [`Partition`] only stores the
//! per-client sizes (cheap) up front, and materializes a client's
//! [`ClientData`] on first touch. Each shard is a pure function of
//! (dataset kind, client id, seed) — materialization order, caching, and
//! eviction can never change values. Under per-round sampling the driver
//! points the cache at the active participant set
//! ([`Partition::retain`]), so at `--clients 1000, p=0.05` only ~50
//! shards are resident; out-of-sample reads (per-round evaluation) hand
//! back transient shards that drop after use.

use std::collections::HashMap;
use std::sync::{Arc, RwLock};

use anyhow::{ensure, Result};

use crate::data::rng::Rng;
use crate::data::synthetic::{Family, SyntheticDataset, PIXELS};
use crate::engine::stable_shard;

pub const CLASSES_PER_FAMILY: usize = 10;

/// Which partition protocol to run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DatasetKind {
    MixedCifar,
    MixedNonIid,
}

impl std::str::FromStr for DatasetKind {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self> {
        match s {
            "mixed-cifar" => Ok(DatasetKind::MixedCifar),
            "mixed-noniid" => Ok(DatasetKind::MixedNonIid),
            other => anyhow::bail!("unknown dataset `{other}` (mixed-cifar | mixed-noniid)"),
        }
    }
}

impl DatasetKind {
    pub fn name(&self) -> &'static str {
        match self {
            DatasetKind::MixedCifar => "mixed-cifar",
            DatasetKind::MixedNonIid => "mixed-noniid",
        }
    }

    /// Size of the global label space (classifier head).
    pub fn num_classes(&self) -> usize {
        match self {
            DatasetKind::MixedCifar => CLASSES_PER_FAMILY,
            DatasetKind::MixedNonIid => CLASSES_PER_FAMILY * Family::ALL.len(),
        }
    }

    /// Artifact tag prefix for this label-space size (`c10` / `c50`).
    pub fn tag(&self) -> &'static str {
        match self {
            DatasetKind::MixedCifar => "c10",
            DatasetKind::MixedNonIid => "c50",
        }
    }
}

/// Materialized train/test split for one client.
pub struct ClientData {
    pub id: usize,
    pub family: Family,
    /// global-space class labels this client can emit
    pub classes: Vec<usize>,
    pub train_x: Vec<f32>,
    pub train_y: Vec<f32>,
    pub test_x: Vec<f32>,
    pub test_y: Vec<f32>,
}

impl ClientData {
    pub fn train_len(&self) -> usize {
        self.train_y.len()
    }

    pub fn test_len(&self) -> usize {
        self.test_y.len()
    }
}

/// Per-client train-set sizes under a geometric imbalance factor.
/// `imbalance = 1.0` gives equal sizes; `2.0` makes each client twice the
/// previous one's size (normalized to keep the total close to n*base).
pub fn imbalanced_sizes(n_clients: usize, base: usize, imbalance: f64) -> Vec<usize> {
    if uniform_imbalance(imbalance) {
        return vec![base; n_clients];
    }
    let weights: Vec<f64> = (0..n_clients).map(|i| imbalance.powi(i as i32)).collect();
    let total: f64 = weights.iter().sum();
    weights
        .iter()
        .map(|w| ((w / total) * (base * n_clients) as f64).round().max(32.0) as usize)
        .collect()
}

fn uniform_imbalance(imbalance: f64) -> bool {
    (imbalance - 1.0).abs() < 1e-9
}

/// Number of hash-map shards the partition cache spreads clients over —
/// per-shard `RwLock`s replace one lock per client, so a 100000-client
/// fleet carries 16 locks, not 100000.
pub const PARTITION_SHARDS: usize = 16;

/// The experiment's client shards, generated lazily on first touch.
///
/// Residency follows the driver's sampling discipline: ids inside the
/// `keep` set ([`Partition::retain`]; everyone by default) are cached on
/// materialization, everything else is handed out as a transient
/// `Arc<ClientData>` that frees itself when the caller drops it. Shards
/// are pure functions of (kind, id, seed), so a regenerated shard is
/// bit-identical to the evicted one.
///
/// Every per-instance allocation is O(resident ∪ keep), never O(fleet):
/// the cache is [`PARTITION_SHARDS`] id-keyed maps (placement =
/// [`stable_shard`]), the keep set is the driver's sorted sample, and
/// train-set sizes are computed on demand from the imbalance geometry —
/// bit-identical to the eager [`imbalanced_sizes`] table.
pub struct Partition {
    kind: DatasetKind,
    n_clients: usize,
    train_per_client: usize,
    imbalance: f64,
    /// `sum(imbalance^i for i in 0..n)` — the normalizer `imbalanced_sizes`
    /// divides by, precomputed with the same sequential sum so lazy
    /// lookups reproduce the eager table bit-for-bit. Unused (0.0) when
    /// the imbalance is uniform.
    weight_total: f64,
    test_per_client: usize,
    seed: u64,
    /// `None` = keep everyone (full participation); `Some` holds the
    /// driver's sorted sample.
    keep: Option<Vec<usize>>,
    shards: Vec<RwLock<HashMap<usize, Arc<ClientData>>>>,
}

impl Partition {
    pub fn new(
        kind: DatasetKind,
        n_clients: usize,
        train_per_client: usize,
        test_per_client: usize,
        imbalance: f64,
        seed: u64,
    ) -> Result<Self> {
        ensure!(n_clients > 0, "need at least one client");
        let weight_total = if uniform_imbalance(imbalance) {
            0.0
        } else {
            (0..n_clients).map(|i| imbalance.powi(i as i32)).sum()
        };
        Ok(Self {
            kind,
            n_clients,
            train_per_client,
            imbalance,
            weight_total,
            test_per_client,
            seed,
            keep: None,
            shards: (0..PARTITION_SHARDS).map(|_| RwLock::new(HashMap::new())).collect(),
        })
    }

    pub fn len(&self) -> usize {
        self.n_clients
    }

    pub fn is_empty(&self) -> bool {
        self.n_clients == 0
    }

    /// The client's train-set size, without materializing the shard
    /// (aggregation weights need only this). Computed on demand; equals
    /// `imbalanced_sizes(n, base, imbalance)[id]` exactly.
    pub fn train_len(&self, id: usize) -> usize {
        debug_assert!(id < self.n_clients, "client {id} out of range");
        if uniform_imbalance(self.imbalance) {
            return self.train_per_client;
        }
        let w = self.imbalance.powi(id as i32);
        ((w / self.weight_total) * (self.train_per_client * self.n_clients) as f64)
            .round()
            .max(32.0) as usize
    }

    fn kept(&self, id: usize) -> bool {
        match &self.keep {
            None => true,
            Some(keep) => keep.binary_search(&id).is_ok(),
        }
    }

    /// One client's shard, materializing on first touch. Cached only for
    /// ids inside the current keep set; other reads are transient.
    pub fn get(&self, id: usize) -> Arc<ClientData> {
        let shard = &self.shards[stable_shard(id, PARTITION_SHARDS)];
        if let Some(c) = shard.read().expect("partition lock").get(&id) {
            return c.clone();
        }
        let data = Arc::new(self.generate(id));
        if self.kept(id) {
            let mut w = shard.write().expect("partition lock");
            if let Some(c) = w.get(&id) {
                // another worker materialized concurrently — same bits
                return c.clone();
            }
            w.insert(id, data.clone());
        }
        data
    }

    /// Test-split-only read for evaluation sweeps. Cached shards come
    /// back whole; an out-of-cache id generates **only** its test split
    /// (train vectors left empty — train and test draw from independent
    /// sample-index ranges, so the test bits are identical to the full
    /// shard's). Never caches: at `--clients 1000, p=0.05` the per-round
    /// eval sweep skips ~2/3 of the generation work (train synthesis +
    /// shuffle) for the ~950 out-of-sample clients.
    pub fn get_for_eval(&self, id: usize) -> Arc<ClientData> {
        let shard = &self.shards[stable_shard(id, PARTITION_SHARDS)];
        if let Some(c) = shard.read().expect("partition lock").get(&id) {
            return c.clone();
        }
        if self.kept(id) {
            // resident set: materialize and cache the full shard
            return self.get(id);
        }
        Arc::new(self.generate_sized(id, 0))
    }

    /// Point the cache at `keep` (ascending ids): cached shards outside
    /// the set are dropped, and future out-of-set reads stay transient.
    /// The driver calls this with the round's participant set whenever
    /// per-round sampling is active, mirroring the [`ClientStateStore`]
    /// residency discipline. Costs O(resident + |keep|) — the cache is
    /// walked, never the fleet.
    ///
    /// [`ClientStateStore`]: crate::driver::ClientStateStore
    pub fn retain(&mut self, keep: &[usize]) {
        for shard in &mut self.shards {
            shard
                .get_mut()
                .expect("partition lock")
                // detlint: allow(D01, per-id membership predicate: visit order cannot affect which entries survive)
                .retain(|id, _| keep.binary_search(id).is_ok());
        }
        self.keep = Some(keep.to_vec());
    }

    /// Ids whose shards are currently resident (tests/introspection),
    /// sorted ascending.
    pub fn materialized_ids(&self) -> Vec<usize> {
        let mut ids: Vec<usize> = self
            .shards
            .iter()
            .flat_map(|s| {
                s.read()
                    .expect("partition lock")
                    // detlint: allow(D01, ids are collected then sort_unstable'd below before anyone sees them)
                    .keys()
                    .copied()
                    .collect::<Vec<_>>()
            })
            .collect();
        ids.sort_unstable();
        ids
    }

    pub fn materialized_count(&self) -> usize {
        self.shards
            .iter()
            .map(|s| s.read().expect("partition lock").len())
            .sum()
    }

    /// Generate client `id`'s shard — a pure function of
    /// (kind, id, seed); bit-identical no matter when or how often it
    /// runs.
    fn generate(&self, id: usize) -> ClientData {
        self.generate_sized(id, self.train_len(id))
    }

    /// `generate` with an explicit train-set size: `0` skips train
    /// synthesis entirely (test generation uses an independent index
    /// range, so its bits do not depend on the train size).
    fn generate_sized(&self, id: usize, n_train: usize) -> ClientData {
        match self.kind {
            DatasetKind::MixedCifar => {
                // one family, 5 fixed 2-class shards assigned round-robin
                let ds =
                    SyntheticDataset::new(Family::Cifar10Like, CLASSES_PER_FAMILY, self.seed);
                let shard = id % (CLASSES_PER_FAMILY / 2);
                let classes = vec![2 * shard, 2 * shard + 1];
                materialize(
                    &ds, id, Family::Cifar10Like, &classes, 0, n_train,
                    self.test_per_client, self.seed,
                )
            }
            DatasetKind::MixedNonIid => {
                let family = Family::ALL[id % Family::ALL.len()];
                let ds = SyntheticDataset::new(family, CLASSES_PER_FAMILY, self.seed);
                let classes: Vec<usize> = (0..CLASSES_PER_FAMILY).collect();
                let offset = (id % Family::ALL.len()) * CLASSES_PER_FAMILY;
                materialize(
                    &ds, id, family, &classes, offset, n_train,
                    self.test_per_client, self.seed,
                )
            }
        }
    }
}

/// Build the partition for an experiment (shards generate lazily on
/// first touch — see [`Partition`]).
pub fn build_partition(
    kind: DatasetKind,
    n_clients: usize,
    train_per_client: usize,
    test_per_client: usize,
    imbalance: f64,
    seed: u64,
) -> Result<Partition> {
    Partition::new(kind, n_clients, train_per_client, test_per_client, imbalance, seed)
}

fn materialize(
    ds: &SyntheticDataset,
    id: usize,
    family: Family,
    classes: &[usize],
    label_offset: usize,
    n_train: usize,
    n_test: usize,
    seed: u64,
) -> ClientData {
    // distinct index ranges per client and per split => no duplicated samples
    let base = (id as u64) << 40;
    let (train_x, train_y) = ds.generate(classes, n_train, label_offset, base);
    let (test_x, test_y) = ds.generate(classes, n_test, label_offset, base + (1 << 30));
    // shuffle train set deterministically so round-robin class order does
    // not leak into batch composition
    let mut rng = Rng::new(seed).derive("partition-shuffle", id as u64);
    let perm = rng.permutation(n_train);
    let mut sx = vec![0.0f32; train_x.len()];
    let mut sy = vec![0.0f32; train_y.len()];
    for (dst, &src) in perm.iter().enumerate() {
        sx[dst * PIXELS..(dst + 1) * PIXELS]
            .copy_from_slice(&train_x[src * PIXELS..(src + 1) * PIXELS]);
        sy[dst] = train_y[src];
    }
    ClientData {
        id,
        family,
        classes: classes.iter().map(|c| c + label_offset).collect(),
        train_x: sx,
        train_y: sy,
        test_x,
        test_y,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mixed_cifar_shards_are_disjoint_pairs() {
        let parts = build_partition(DatasetKind::MixedCifar, 5, 64, 32, 1.0, 3).unwrap();
        let mut all: Vec<usize> = (0..5).flat_map(|i| parts.get(i).classes.clone()).collect();
        all.sort_unstable();
        assert_eq!(all, (0..10).collect::<Vec<_>>());
        for i in 0..5 {
            let c = parts.get(i);
            assert_eq!(c.classes.len(), 2);
            for &y in &c.train_y {
                assert!(c.classes.contains(&(y as usize)));
            }
        }
    }

    #[test]
    fn mixed_noniid_label_spaces_disjoint() {
        let parts = build_partition(DatasetKind::MixedNonIid, 5, 64, 32, 1.0, 3).unwrap();
        for i in 0..5 {
            let c = parts.get(i);
            assert_eq!(c.family, Family::ALL[i]);
            for &y in &c.train_y {
                let y = y as usize;
                assert!(y >= i * 10 && y < (i + 1) * 10);
            }
        }
    }

    #[test]
    fn sizes_and_determinism() {
        let a = build_partition(DatasetKind::MixedCifar, 3, 100, 40, 1.0, 9).unwrap();
        let b = build_partition(DatasetKind::MixedCifar, 3, 100, 40, 1.0, 9).unwrap();
        assert_eq!(a.get(0).train_len(), 100);
        assert_eq!(a.train_len(0), 100, "size known without materializing");
        assert_eq!(a.get(0).test_len(), 40);
        // materialization order must not matter: touch b back-to-front
        let b2 = b.get(2).train_y.clone();
        let b1 = b.get(1).train_x.clone();
        assert_eq!(a.get(1).train_x, b1);
        assert_eq!(a.get(2).train_y, b2);
    }

    #[test]
    fn imbalance_skews_sizes() {
        let sizes = imbalanced_sizes(4, 100, 2.0);
        assert!(sizes[3] > sizes[0] * 4);
        assert_eq!(imbalanced_sizes(4, 100, 1.0), vec![100; 4]);
    }

    #[test]
    fn train_test_disjoint() {
        let parts = build_partition(DatasetKind::MixedCifar, 1, 16, 16, 1.0, 5).unwrap();
        let c = parts.get(0);
        // same class list, but distinct sample index ranges => images differ
        assert_ne!(&c.train_x[..PIXELS], &c.test_x[..PIXELS]);
    }

    #[test]
    fn only_sampled_clients_shards_materialize_at_scale() {
        // the ROADMAP scale point: 1000 clients, p = 0.05 — per-round
        // residency must track the ~50-client sample, not the fleet.
        // Construction is cheap because nothing materializes up front.
        let mut part =
            Partition::new(DatasetKind::MixedCifar, 1000, 64, 32, 1.0, 7).unwrap();
        assert_eq!(part.len(), 1000);
        assert_eq!(part.materialized_count(), 0, "construction generates nothing");
        assert_eq!(part.train_len(999), 64, "sizes known without data");

        let mut rng = Rng::new(7);
        for round in 0..4 {
            // a seeded 5% sample, like SampledSync would draw
            let mut sample = rng.derive("test-sample", round).permutation(1000);
            sample.truncate(50);
            sample.sort_unstable();
            part.retain(&sample);
            for &i in &sample {
                let shard = part.get(i);
                assert_eq!(shard.id, i);
                assert_eq!(shard.train_len(), 64);
            }
            assert_eq!(
                part.materialized_ids(),
                sample,
                "round {round}: exactly the sampled shards are resident"
            );
        }

        // an out-of-sample read (eval sweep) is transient: it must not
        // grow the resident set
        let resident_before = part.materialized_count();
        let outside = (0..1000usize)
            .find(|i| part.materialized_ids().binary_search(i).is_err())
            .unwrap();
        let transient = part.get(outside);
        assert_eq!(transient.id, outside);
        assert_eq!(part.materialized_count(), resident_before);
    }

    #[test]
    fn get_for_eval_skips_train_synthesis_without_changing_test_bits() {
        let mut part = Partition::new(DatasetKind::MixedCifar, 8, 64, 32, 1.0, 13).unwrap();
        part.retain(&[2]);
        // out-of-sample: test split identical to the full shard's, train
        // skipped, nothing cached
        let full = Partition::new(DatasetKind::MixedCifar, 8, 64, 32, 1.0, 13)
            .unwrap()
            .get(5);
        let eval_view = part.get_for_eval(5);
        assert_eq!(eval_view.test_x, full.test_x, "test bits independent of train");
        assert_eq!(eval_view.test_y, full.test_y);
        assert_eq!(eval_view.train_len(), 0, "train synthesis skipped");
        assert!(part.materialized_ids().is_empty(), "eval reads never cache");
        // resident: the full cached shard comes back
        let resident = part.get(2);
        assert_eq!(resident.train_len(), 64);
        let resident_eval = part.get_for_eval(2);
        assert_eq!(resident_eval.train_len(), 64, "cached shard returned whole");
        assert_eq!(part.materialized_ids(), vec![2]);
    }

    #[test]
    fn shard_lazy_sizes_match_eager_table() {
        // the on-demand size formula must reproduce the eager table
        // exactly — same powi, same sequential normalizer sum
        for &(n, base, imb) in &[(64usize, 100usize, 1.07f64), (16, 48, 2.0), (40, 64, 0.93)] {
            let eager = imbalanced_sizes(n, base, imb);
            let part = Partition::new(DatasetKind::MixedCifar, n, base, 32, imb, 3).unwrap();
            let lazy: Vec<usize> = (0..n).map(|i| part.train_len(i)).collect();
            assert_eq!(lazy, eager, "n={n} base={base} imbalance={imb}");
        }
    }

    #[test]
    fn shard_fleet_scale_partition_is_o_sample() {
        // 100000 clients, p = 0.005: construction allocates 16 empty
        // shard maps, and a round touches only the ~500-id sample
        let mut part =
            Partition::new(DatasetKind::MixedNonIid, 100_000, 64, 32, 1.0, 17).unwrap();
        assert_eq!(part.len(), 100_000);
        assert_eq!(part.materialized_count(), 0);
        assert_eq!(part.train_len(99_999), 64);
        let sample: Vec<usize> = (0..500).map(|j| j * 199 + 3).collect();
        part.retain(&sample);
        for &i in sample.iter().step_by(50) {
            assert_eq!(part.get(i).id, i);
        }
        assert_eq!(part.materialized_count(), 10, "only touched sampled ids cached");
        // out-of-sample reads stay transient even at fleet scale
        let t = part.get(99_998);
        assert_eq!(t.id, 99_998);
        assert_eq!(part.materialized_count(), 10);
        // next round's sample evicts the previous one
        part.retain(&[7, 8, 9]);
        assert!(part.materialized_ids().is_empty());
    }

    #[test]
    fn eviction_and_regeneration_are_value_stable() {
        let mut part = Partition::new(DatasetKind::MixedNonIid, 6, 64, 32, 1.3, 11).unwrap();
        let first = part.get(4);
        let (x0, y0) = (first.train_x.clone(), first.train_y.clone());
        drop(first);
        part.retain(&[0, 1]); // evicts 4's cached shard (0/1 were never touched)
        assert!(part.materialized_ids().is_empty());
        let again = part.get(4); // transient regeneration
        assert_eq!(again.train_x, x0, "regenerated shard is bit-identical");
        assert_eq!(again.train_y, y0);
        part.retain(&[4]);
        let cached = part.get(4);
        assert_eq!(cached.train_x, x0);
        assert_eq!(part.materialized_ids(), vec![4]);
    }
}
