//! Client partition protocols from the paper (§4.1):
//!
//! * **Mixed-CIFAR** — one 10-class family; the classes are divided into 5
//!   subsets of 2 distinct classes and every client gets one subset
//!   (low, consistent inter-client heterogeneity). Global head: 10.
//! * **Mixed-NonIID** — five families, one per client; labels live in a
//!   disjoint global space of 5 x 10 = 50 classes (high, *variable*
//!   pairwise heterogeneity: the mnist-like/fmnist-like pair is close,
//!   cifar100-like is far from everything).
//!
//! Supports client dataset-size imbalance (`imbalance` skews sizes
//! geometrically) so FedNova's normalized averaging has real work to do.

use anyhow::{ensure, Result};

use crate::data::rng::Rng;
use crate::data::synthetic::{Family, SyntheticDataset, PIXELS};

pub const CLASSES_PER_FAMILY: usize = 10;

/// Which partition protocol to run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DatasetKind {
    MixedCifar,
    MixedNonIid,
}

impl std::str::FromStr for DatasetKind {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self> {
        match s {
            "mixed-cifar" => Ok(DatasetKind::MixedCifar),
            "mixed-noniid" => Ok(DatasetKind::MixedNonIid),
            other => anyhow::bail!("unknown dataset `{other}` (mixed-cifar | mixed-noniid)"),
        }
    }
}

impl DatasetKind {
    pub fn name(&self) -> &'static str {
        match self {
            DatasetKind::MixedCifar => "mixed-cifar",
            DatasetKind::MixedNonIid => "mixed-noniid",
        }
    }

    /// Size of the global label space (classifier head).
    pub fn num_classes(&self) -> usize {
        match self {
            DatasetKind::MixedCifar => CLASSES_PER_FAMILY,
            DatasetKind::MixedNonIid => CLASSES_PER_FAMILY * Family::ALL.len(),
        }
    }

    /// Artifact tag prefix for this label-space size (`c10` / `c50`).
    pub fn tag(&self) -> &'static str {
        match self {
            DatasetKind::MixedCifar => "c10",
            DatasetKind::MixedNonIid => "c50",
        }
    }
}

/// Materialized train/test split for one client.
pub struct ClientData {
    pub id: usize,
    pub family: Family,
    /// global-space class labels this client can emit
    pub classes: Vec<usize>,
    pub train_x: Vec<f32>,
    pub train_y: Vec<f32>,
    pub test_x: Vec<f32>,
    pub test_y: Vec<f32>,
}

impl ClientData {
    pub fn train_len(&self) -> usize {
        self.train_y.len()
    }

    pub fn test_len(&self) -> usize {
        self.test_y.len()
    }
}

/// Per-client train-set sizes under a geometric imbalance factor.
/// `imbalance = 1.0` gives equal sizes; `2.0` makes each client twice the
/// previous one's size (normalized to keep the total close to n*base).
pub fn imbalanced_sizes(n_clients: usize, base: usize, imbalance: f64) -> Vec<usize> {
    if (imbalance - 1.0).abs() < 1e-9 {
        return vec![base; n_clients];
    }
    let weights: Vec<f64> = (0..n_clients).map(|i| imbalance.powi(i as i32)).collect();
    let total: f64 = weights.iter().sum();
    weights
        .iter()
        .map(|w| ((w / total) * (base * n_clients) as f64).round().max(32.0) as usize)
        .collect()
}

/// Build the full partition for an experiment.
pub fn build_partition(
    kind: DatasetKind,
    n_clients: usize,
    train_per_client: usize,
    test_per_client: usize,
    imbalance: f64,
    seed: u64,
) -> Result<Vec<ClientData>> {
    ensure!(n_clients > 0, "need at least one client");
    let sizes = imbalanced_sizes(n_clients, train_per_client, imbalance);
    let mut clients = Vec::with_capacity(n_clients);

    match kind {
        DatasetKind::MixedCifar => {
            // one family, 5 fixed 2-class shards assigned round-robin
            let ds = SyntheticDataset::new(Family::Cifar10Like, CLASSES_PER_FAMILY, seed);
            for id in 0..n_clients {
                let shard = id % (CLASSES_PER_FAMILY / 2);
                let classes = vec![2 * shard, 2 * shard + 1];
                clients.push(materialize(
                    &ds, id, Family::Cifar10Like, &classes, 0, sizes[id],
                    test_per_client, seed,
                ));
            }
        }
        DatasetKind::MixedNonIid => {
            for id in 0..n_clients {
                let family = Family::ALL[id % Family::ALL.len()];
                let ds = SyntheticDataset::new(family, CLASSES_PER_FAMILY, seed);
                let classes: Vec<usize> = (0..CLASSES_PER_FAMILY).collect();
                let offset = (id % Family::ALL.len()) * CLASSES_PER_FAMILY;
                clients.push(materialize(
                    &ds, id, family, &classes, offset, sizes[id],
                    test_per_client, seed,
                ));
            }
        }
    }
    Ok(clients)
}

fn materialize(
    ds: &SyntheticDataset,
    id: usize,
    family: Family,
    classes: &[usize],
    label_offset: usize,
    n_train: usize,
    n_test: usize,
    seed: u64,
) -> ClientData {
    // distinct index ranges per client and per split => no duplicated samples
    let base = (id as u64) << 40;
    let (train_x, train_y) = ds.generate(classes, n_train, label_offset, base);
    let (test_x, test_y) = ds.generate(classes, n_test, label_offset, base + (1 << 30));
    // shuffle train set deterministically so round-robin class order does
    // not leak into batch composition
    let mut rng = Rng::new(seed).derive("partition-shuffle", id as u64);
    let perm = rng.permutation(n_train);
    let mut sx = vec![0.0f32; train_x.len()];
    let mut sy = vec![0.0f32; train_y.len()];
    for (dst, &src) in perm.iter().enumerate() {
        sx[dst * PIXELS..(dst + 1) * PIXELS]
            .copy_from_slice(&train_x[src * PIXELS..(src + 1) * PIXELS]);
        sy[dst] = train_y[src];
    }
    ClientData {
        id,
        family,
        classes: classes.iter().map(|c| c + label_offset).collect(),
        train_x: sx,
        train_y: sy,
        test_x,
        test_y,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mixed_cifar_shards_are_disjoint_pairs() {
        let parts = build_partition(DatasetKind::MixedCifar, 5, 64, 32, 1.0, 3).unwrap();
        let mut all: Vec<usize> = parts.iter().flat_map(|c| c.classes.clone()).collect();
        all.sort_unstable();
        assert_eq!(all, (0..10).collect::<Vec<_>>());
        for c in &parts {
            assert_eq!(c.classes.len(), 2);
            for &y in &c.train_y {
                assert!(c.classes.contains(&(y as usize)));
            }
        }
    }

    #[test]
    fn mixed_noniid_label_spaces_disjoint() {
        let parts = build_partition(DatasetKind::MixedNonIid, 5, 64, 32, 1.0, 3).unwrap();
        for (i, c) in parts.iter().enumerate() {
            assert_eq!(c.family, Family::ALL[i]);
            for &y in &c.train_y {
                let y = y as usize;
                assert!(y >= i * 10 && y < (i + 1) * 10);
            }
        }
    }

    #[test]
    fn sizes_and_determinism() {
        let a = build_partition(DatasetKind::MixedCifar, 3, 100, 40, 1.0, 9).unwrap();
        let b = build_partition(DatasetKind::MixedCifar, 3, 100, 40, 1.0, 9).unwrap();
        assert_eq!(a[0].train_len(), 100);
        assert_eq!(a[0].test_len(), 40);
        assert_eq!(a[1].train_x, b[1].train_x);
        assert_eq!(a[2].train_y, b[2].train_y);
    }

    #[test]
    fn imbalance_skews_sizes() {
        let sizes = imbalanced_sizes(4, 100, 2.0);
        assert!(sizes[3] > sizes[0] * 4);
        assert_eq!(imbalanced_sizes(4, 100, 1.0), vec![100; 4]);
    }

    #[test]
    fn train_test_disjoint() {
        let parts = build_partition(DatasetKind::MixedCifar, 1, 16, 16, 1.0, 5).unwrap();
        // same class list, but distinct sample index ranges => images differ
        assert_ne!(&parts[0].train_x[..PIXELS], &parts[0].test_x[..PIXELS]);
    }
}
