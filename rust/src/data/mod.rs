//! Data substrate: deterministic RNG, procedural image families, the
//! paper's two partition protocols (Mixed-CIFAR, Mixed-NonIID), and batch
//! iteration.
//!
//! The paper evaluates on MNIST/FMNIST/Not-MNIST/CIFAR-10/CIFAR-100. Those
//! are not available here, so `synthetic` builds five procedural 32x32x3
//! image families with controlled class structure and *variable pairwise
//! heterogeneity* — the property the experiments actually stress (see
//! DESIGN.md §1 for the substitution argument).

pub mod batcher;
pub mod partition;
pub mod rng;
pub mod synthetic;

pub use batcher::{BatchIter, Batch};
pub use partition::{build_partition, ClientData, DatasetKind, Partition};
pub use rng::Rng;
pub use synthetic::{Family, SyntheticDataset};
