//! # AdaSplit — adaptive trade-offs for resource-constrained distributed deep learning
//!
//! A production-grade reproduction of *AdaSplit* (Chopra et al., 2021) as a
//! three-layer Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the distributed-training coordinator: protocol
//!   state machines for AdaSplit and six baselines (SL-basic, SplitFed,
//!   FedAvg, FedProx, Scaffold, FedNova), the UCB orchestrator, synthetic
//!   non-IID data substrates, analytic FLOP/bandwidth accounting, and the
//!   C3-Score metric.
//! * **L2** — JAX compute graphs (`python/compile/model.py`), AOT-lowered to
//!   HLO text once at build time (`make artifacts`).
//! * **L1** — Pallas kernels (NT-Xent loss, masked Adam) called from L2.
//!
//! Python never runs on the training path: [`runtime`] loads the HLO text
//! artifacts via the PJRT C API (`xla` crate) and executes them directly.
//!
//! Every protocol implements the [`driver`] module's client-step /
//! server-merge `Protocol` trait; one generic `RoundDriver` owns the
//! round loop, per-round client sampling (`--participation p`, pooled
//! client state with spill-to-disk), bounded-staleness async scheduling
//! over a seeded per-client speed model (`--staleness-bound s`,
//! `--client-speeds`, simulated wall-clock in every report), an online
//! UCB controller that re-picks the staleness bound from each window's
//! C3-shaped reward (`--adaptive-bound`, DESIGN.md §9), and the
//! [`engine`] fan-out (`--threads N`, default = host parallelism).
//! Results are merged in client-id order so parallel runs are
//! bit-identical to serial ones (DESIGN.md §5–§7). `--engine events`
//! swaps the round barrier for the [`sim`] module's discrete-event
//! driver — a seeded event heap with pluggable server merge policies
//! (`--merge-policy arrival | batch:K | window:DT`, DESIGN.md §11) —
//! while the default `round` policy replays the round schedulers
//! bit-for-bit as degenerate event streams.
//!
//! ## Quickstart
//!
//! ```no_run
//! use adasplit::config::ExperimentConfig;
//! use adasplit::protocols::run_protocol;
//! use adasplit::runtime::Runtime;
//!
//! let rt = Runtime::load("artifacts").unwrap();
//! let cfg = ExperimentConfig::quick_test();
//! let result = run_protocol(&rt, &cfg).unwrap();
//! println!("accuracy={:.2}% c3={:.3}", result.accuracy, result.c3_score);
//! ```

pub mod bench;
pub mod config;
pub mod data;
pub mod detlint;
pub mod driver;
pub mod engine;
pub mod util;
pub mod metrics;
pub mod model;
pub mod orchestrator;
pub mod protocols;
pub mod report;
pub mod runtime;
pub mod sim;

pub use config::ExperimentConfig;
pub use protocols::{run_protocol, RunResult};
pub use runtime::Runtime;
