//! AdaSplit launcher: run any protocol / dataset / sweep from the CLI or a
//! TOML-subset config file.
//!
//! ```text
//! adasplit run --protocol ada-split --dataset mixed-cifar --rounds 20
//! adasplit run --config configs/table1_noniid.toml
//! adasplit compare --dataset mixed-noniid --rounds 10
//! adasplit info
//! ```
//!
//! The argument parser is in-tree (no registry crates available offline —
//! see Cargo.toml).

use anyhow::{bail, Context, Result};

use adasplit::config::{ExperimentConfig, ProtocolKind};
use adasplit::data::DatasetKind;
use adasplit::driver::SpeedPreset;
use adasplit::engine::par_indexed;
use adasplit::protocols::{run_protocol_recorded, run_seeds};
use adasplit::report::ResultTable;
use adasplit::runtime::Runtime;
use adasplit::sim::{ChurnSpec, EngineKind, MergePolicyKind, RateScheduleSpec};

const USAGE: &str = "\
adasplit — AdaSplit distributed-training coordinator

USAGE:
  adasplit [--artifacts DIR] <command> [options]

COMMANDS:
  run       run one protocol end to end and print the result row
  compare   run every protocol on one dataset, print the paper-style table
  info      print manifest/artifact info

RUN OPTIONS:
  --config PATH          load a TOML config (other flags override it)
  --protocol ID          ada-split | sl-basic | split-fed | fed-avg |
                         fed-prox | scaffold | fed-nova   [ada-split]
  --dataset ID           mixed-cifar | mixed-noniid       [mixed-cifar]
  --rounds N             training rounds                  [20]
  --samples N            train samples per client         [512]
  --test-samples N       test samples per client          [256]
  --seed N               experiment seed                  [0]
  --kappa X --eta X --mu X --beta X --lambda X
  --server-grad          Table-5 ablation: send server gradient to client
  --imbalance X          geometric client-size skew       [1.0]
  --clients N            number of clients                [5]
  --participation P      per-round client sampling fraction in (0,1];
                         < 1 samples ceil(P*N) clients per round and
                         spills inactive client state to disk   [1.0]
  --staleness-bound S    async bounded-staleness scheduler: clients run on
                         per-client virtual clocks and merged updates may
                         be up to S rounds stale (omit = synchronous;
                         S=0 + uniform speeds == synchronous bit-for-bit)
  --client-speeds M      per-client speed model: uniform |
                         lognormal[:sigma] | stragglers      [uniform]
  --straggler-frac F     fraction of 10x-slow clients under the
                         stragglers speed model              [0.1]
  --stale-decay D        aggregation down-weight per round of staleness,
                         in (0,1]; affects the weighted-aggregation
                         protocols (FL family, SplitFed) — AdaSplit and
                         SL-basic see staleness only as participation
                         cadence (DESIGN.md §7)              [0.5]
  --delayed-gradients    true delayed-gradient staleness: a client merging
                         S rounds stale trains against the model snapshot
                         it pulled S rounds ago (per-client versioning,
                         DESIGN.md §8) instead of the current one; needs
                         --staleness-bound. Off = cadence-only (PR 3).
                         Affects protocols whose clients download server
                         state (the FL family); AdaSplit / SL-basic /
                         SplitFed clients pull none, so they stay
                         cadence-only by construction
  --adaptive-bound       adaptive staleness bound: a seeded UCB1
                         controller re-picks the AsyncBounded bound from
                         the candidate set every --adapt-window rounds,
                         rewarded by each window's C3-shaped accuracy /
                         sim-time trade-off (DESIGN.md §9); needs
                         --staleness-bound (the arm ceiling). Switches
                         only land on window boundaries
  --adapt-window W       rounds per adaptation window          [5]
  --adapt-arms LIST      comma-separated candidate bounds, clipped to
                         --staleness-bound (a singleton list reproduces
                         the fixed-bound run bit-for-bit) [0,1,2,4,8]
  --engine E             driver engine: rounds (barrier loop) | events
                         (discrete-event heap over per-client virtual
                         clocks, DESIGN.md §11)               [rounds]
  --merge-policy P       events-engine server merge policy: round
                         (degenerate — replays the configured scheduler
                         bit-for-bit) | arrival | batch:K | window:DT
                         (needs --engine events)              [round]
  --churn SPEC           seeded open-world churn on the events engine:
                         `join:X,leave:Y` Poisson rates per sim-time unit
                         (either side omittable; needs a continuous
                         --merge-policy, DESIGN.md §12)
  --rate-schedule SPEC   time-varying client speeds on the events engine:
                         `diurnal:PERIOD:AMP` and/or `flaky:RATE:SLOW:LEN`
                         joined with `+` (needs a continuous merge policy)
  --trace-out PATH       record the applied scenario stream as JSONL
  --trace-in PATH        replay a recorded scenario trace bit-identically
                         (excludes --churn / --rate-schedule)
  --threads N            engine worker threads (0 = host parallelism) [0]
  --curve-out PATH       write the per-round curve CSV
  --trace                print per-iteration orchestrator traces

COMPARE OPTIONS:
  --dataset ID  --rounds N  --samples N  --test-samples N  --seeds N
  --participation P      per-round client sampling fraction    [1.0]
  --staleness-bound S    async bounded-staleness scheduling (see RUN)
  --client-speeds M      per-client speed model (see RUN)  [uniform]
  --straggler-frac F     stragglers-preset slow fraction       [0.1]
  --stale-decay D        staleness down-weight (see RUN)       [0.5]
  --delayed-gradients    per-client model versioning (see RUN)
  --adaptive-bound       UCB-adaptive staleness bound (see RUN)
  --adapt-window W       rounds per adaptation window          [5]
  --adapt-arms LIST      candidate bounds for the controller (see RUN)
  --engine E             rounds | events driver engine (see RUN) [rounds]
  --merge-policy P       events-engine merge policy (see RUN)    [round]
  --churn SPEC           seeded open-world churn (see RUN)
  --rate-schedule SPEC   time-varying client speeds (see RUN)
  --trace-in PATH        replay a recorded scenario trace (see RUN);
                         no --trace-out here — seven protocols would
                         race on one output file
  --threads N            worker threads per run; protocols also run
                         concurrently across the pool      [0 = auto]
";

/// Tiny flag parser: `--key value` pairs plus boolean switches.
struct Args {
    flags: Vec<(String, Option<String>)>,
}

impl Args {
    fn parse(argv: &[String], switches: &[&str]) -> Result<Self> {
        let mut flags = Vec::new();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            let Some(key) = a.strip_prefix("--") else {
                bail!("unexpected argument `{a}`\n\n{USAGE}");
            };
            if switches.contains(&key) {
                flags.push((key.to_string(), None));
                i += 1;
            } else {
                let v = argv
                    .get(i + 1)
                    .with_context(|| format!("--{key} needs a value"))?;
                flags.push((key.to_string(), Some(v.clone())));
                i += 2;
            }
        }
        Ok(Self { flags })
    }

    fn get(&self, key: &str) -> Option<&str> {
        self.flags
            .iter()
            .find(|(k, _)| k == key)
            .and_then(|(_, v)| v.as_deref())
    }

    fn has(&self, key: &str) -> bool {
        self.flags.iter().any(|(k, _)| k == key)
    }

    fn parsed<T: std::str::FromStr>(&self, key: &str) -> Result<Option<T>>
    where
        T::Err: std::fmt::Display,
    {
        match self.get(key) {
            None => Ok(None),
            Some(v) => v
                .parse()
                .map(Some)
                .map_err(|e| anyhow::anyhow!("--{key} {v}: {e}")),
        }
    }
}

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() || argv[0] == "--help" || argv[0] == "-h" {
        print!("{USAGE}");
        return Ok(());
    }

    // global --artifacts may precede the command
    let mut rest = argv.as_slice();
    let mut artifacts = "artifacts".to_string();
    if rest[0] == "--artifacts" {
        artifacts = rest.get(1).context("--artifacts needs a value")?.clone();
        rest = &rest[2..];
    }
    let Some((cmd, tail)) = rest.split_first() else {
        bail!("missing command\n\n{USAGE}");
    };

    let rt = Runtime::load(&artifacts)?;
    match cmd.as_str() {
        "run" => cmd_run(&rt, tail, &artifacts),
        "compare" => cmd_compare(&rt, tail),
        "info" => cmd_info(&rt),
        other => bail!("unknown command `{other}`\n\n{USAGE}"),
    }
}

fn cmd_run(rt: &Runtime, argv: &[String], artifacts: &str) -> Result<()> {
    let args = Args::parse(
        argv,
        &["trace", "server-grad", "delayed-gradients", "adaptive-bound"],
    )?;
    let mut cfg = match args.get("config") {
        Some(path) => ExperimentConfig::load_toml(path)?,
        None => {
            let dataset: DatasetKind = args.get("dataset").unwrap_or("mixed-cifar").parse()?;
            ExperimentConfig::paper_default(dataset)
        }
    };
    if let Some(p) = args.parsed::<ProtocolKind>("protocol")? {
        cfg.protocol = p;
    }
    if let Some(r) = args.parsed("rounds")? {
        cfg.rounds = r;
    }
    if let Some(s) = args.parsed("samples")? {
        cfg.samples_per_client = s;
    }
    if let Some(s) = args.parsed("test-samples")? {
        cfg.test_per_client = s;
    }
    if let Some(s) = args.parsed("seed")? {
        cfg.seed = s;
    }
    if let Some(v) = args.parsed("kappa")? {
        cfg.kappa = v;
    }
    if let Some(v) = args.parsed("eta")? {
        cfg.eta = v;
    }
    if let Some(v) = args.parsed("mu")? {
        cfg.mu = v;
    }
    if let Some(v) = args.parsed("beta")? {
        cfg.beta = v;
    }
    if let Some(v) = args.parsed("lambda")? {
        cfg.lambda = v;
    }
    if let Some(v) = args.parsed("imbalance")? {
        cfg.imbalance = v;
    }
    if let Some(v) = args.parsed("clients")? {
        cfg.clients = v;
    }
    if let Some(v) = args.parsed("participation")? {
        cfg.participation = v;
    }
    if let Some(v) = args.parsed("staleness-bound")? {
        cfg.staleness_bound = Some(v);
    }
    if let Some(v) = args.parsed::<SpeedPreset>("client-speeds")? {
        cfg.client_speeds = v;
    }
    if let Some(v) = args.parsed("straggler-frac")? {
        cfg.straggler_frac = v;
    }
    if let Some(v) = args.parsed("stale-decay")? {
        cfg.stale_decay = v;
    }
    if let Some(v) = args.parsed("adapt-window")? {
        cfg.adapt_window = v;
    }
    if let Some(v) = args.get("adapt-arms") {
        cfg.adapt_arms = Some(adasplit::config::parse_arm_list(v)?);
    }
    if let Some(v) = args.parsed("threads")? {
        cfg.threads = v;
    }
    if let Some(v) = args.parsed("engine")? {
        cfg.engine = v;
    }
    if let Some(v) = args.parsed("merge-policy")? {
        cfg.merge_policy = v;
    }
    if let Some(v) = args.parsed("churn")? {
        cfg.churn = Some(v);
    }
    if let Some(v) = args.parsed("rate-schedule")? {
        cfg.rate_schedule = Some(v);
    }
    if let Some(v) = args.get("trace-out") {
        cfg.trace_out = Some(v.to_string());
    }
    if let Some(v) = args.get("trace-in") {
        cfg.trace_in = Some(v.to_string());
    }
    cfg.adaptive_bound |= args.has("adaptive-bound");
    cfg.delayed_gradients |= args.has("delayed-gradients");
    cfg.server_grad_to_client |= args.has("server-grad");
    cfg.trace |= args.has("trace");
    cfg.artifacts_dir = artifacts.to_string();
    cfg.validate()?;

    let t0 = std::time::Instant::now();
    let (result, recorder) = run_protocol_recorded(rt, &cfg)?;
    if cfg.trace {
        for line in &recorder.trace {
            println!("  {line}");
        }
    }
    for r in &recorder.rounds {
        println!(
            "round {:>3} [{:>6}] loss={:.4} acc={:.2}% bw={:.3}GB cC={:.3}T mask={:.3}",
            r.round, r.phase, r.train_loss, r.accuracy_pct, r.bandwidth_gb,
            r.client_tflops, r.mask_density
        );
    }
    println!(
        "{} on {}: acc={:.2}% (best {:.2}%) bw={:.3}GB compute={:.3} ({:.3}) TFLOPs c3={:.3} simT={:.1} [{:.1}s]",
        result.protocol,
        result.dataset,
        result.accuracy,
        result.best_accuracy,
        result.bandwidth_gb,
        result.client_tflops,
        result.total_tflops,
        result.c3_score,
        result.sim_time,
        t0.elapsed().as_secs_f64()
    );
    if cfg.participation < 1.0 {
        println!(
            "participation={:.2}: {:.1} of {} clients sampled per round (inactive state spilled)",
            result.participation, result.sampled_clients_per_round, cfg.clients
        );
    }
    if let Some(bound) = cfg.staleness_bound {
        let max_stale = recorder.rounds.iter().map(|r| r.max_staleness).max().unwrap_or(0);
        // decay reaches aggregation only through round_weights; AdaSplit
        // and SL-basic aggregate differently, so for them staleness is
        // purely a participation-cadence effect (DESIGN.md §7)
        let decay_note = match cfg.protocol {
            ProtocolKind::AdaSplit | ProtocolKind::SlBasic => " (cadence-only here)",
            _ => "",
        };
        let mode = if cfg.delayed_gradients {
            "true-delay (versioned snapshots)"
        } else {
            "cadence-only"
        };
        println!(
            "async-bounded [{mode}]: staleness bound {bound} (max merged {max_stale}), \
             speeds {}, decay {:.2}{decay_note}, simulated wall-clock {:.2} vs {} synchronous rounds",
            cfg.client_speeds.id(),
            cfg.stale_decay,
            result.sim_time,
            cfg.rounds
        );
    }
    if cfg.adaptive_bound {
        println!(
            "adaptive bound: UCB over {} rounds/window, final bound {}, {} switch(es) \
             (per-round trajectory in the curve CSV `bound` column)",
            cfg.adapt_window, result.final_bound, result.bound_switches
        );
    }
    if cfg.engine == EngineKind::Events {
        println!(
            "event engine: {} events processed, merge policy `{}` \
             (per-row event traffic in the curve CSV `events` column)",
            result.events_processed, result.merge_policy
        );
    }
    if result.scenario != "none" {
        println!(
            "scenario [{}]: {} churn event(s) (joins+leaves), {} rate change(s) applied",
            result.scenario, result.churn_events, result.rate_events
        );
        if let Some(path) = &cfg.trace_out {
            println!("scenario trace written to {path}");
        }
    }
    if let Some(path) = args.get("curve-out") {
        recorder.write_csv(path)?;
        println!("curve written to {path}");
    }
    Ok(())
}

fn cmd_compare(rt: &Runtime, argv: &[String]) -> Result<()> {
    let args = Args::parse(argv, &["delayed-gradients", "adaptive-bound"])?;
    let dataset: DatasetKind = args.get("dataset").unwrap_or("mixed-cifar").parse()?;
    let rounds = args.parsed("rounds")?.unwrap_or(10);
    let samples = args.parsed("samples")?.unwrap_or(256);
    let test = args.parsed("test-samples")?.unwrap_or(128);
    let n_seeds = args.parsed("seeds")?.unwrap_or(1usize);
    let threads = args.parsed("threads")?.unwrap_or(0usize);
    let participation = args.parsed("participation")?.unwrap_or(1.0f64);
    let staleness_bound: Option<usize> = args.parsed("staleness-bound")?;
    let client_speeds: SpeedPreset =
        args.parsed("client-speeds")?.unwrap_or(SpeedPreset::Uniform);
    let straggler_frac = args.parsed("straggler-frac")?.unwrap_or(0.1f64);
    let stale_decay = args.parsed("stale-decay")?.unwrap_or(0.5f64);
    let delayed_gradients = args.has("delayed-gradients");
    let adaptive_bound = args.has("adaptive-bound");
    let adapt_window = args.parsed("adapt-window")?.unwrap_or(5usize);
    let adapt_arms = args
        .get("adapt-arms")
        .map(adasplit::config::parse_arm_list)
        .transpose()?;
    let engine: EngineKind = args.parsed("engine")?.unwrap_or_default();
    let merge_policy: MergePolicyKind = args.parsed("merge-policy")?.unwrap_or_default();
    let churn: Option<ChurnSpec> = args.parsed("churn")?;
    let rate_schedule: Option<RateScheduleSpec> = args.parsed("rate-schedule")?;
    let trace_in = args.get("trace-in").map(str::to_string);
    let seed_list: Vec<u64> = (0..n_seeds as u64).collect();

    let budget = adasplit::engine::ClientPool::new(threads).threads();
    let (outer, per_protocol) = adasplit::engine::split_budget(budget, ProtocolKind::ALL.len());
    let cfgs: Vec<ExperimentConfig> = ProtocolKind::ALL
        .iter()
        .map(|&p| {
            ExperimentConfig::paper_default(dataset)
                .with_protocol(p)
                .with_scale(rounds, samples, test)
                .with_participation(participation)
                .with_staleness_bound(staleness_bound)
                .with_client_speeds(client_speeds)
                .with_straggler_frac(straggler_frac)
                .with_stale_decay(stale_decay)
                .with_delayed_gradients(delayed_gradients)
                .with_adaptive_bound(adaptive_bound)
                .with_adapt_window(adapt_window)
                .with_adapt_arms(adapt_arms.clone())
                .with_engine(engine)
                .with_merge_policy(merge_policy)
                .with_churn(churn)
                .with_rate_schedule(rate_schedule)
                .with_trace_in(trace_in.clone())
                .with_threads(per_protocol)
        })
        .collect();
    for cfg in &cfgs {
        cfg.validate()?;
    }

    // protocol runs are independent: fan them out across the pool. Each
    // run pushes its "done" line through an order-preserving progress
    // channel, so lines stream as protocols finish (in protocol order)
    // instead of printing in one burst after the fan-in.
    let t0 = std::time::Instant::now();
    let (sink, progress) = adasplit::engine::ordered_progress();
    let rows = std::thread::scope(|scope| {
        let cfgs = &cfgs;
        let seed_list = &seed_list;
        let worker = scope.spawn(move || {
            let sink = sink; // dropped when the fan-out ends => progress closes
            par_indexed(outer, cfgs.len(), |i| {
                let row = run_seeds(rt, &cfgs[i], seed_list)?;
                let name = ProtocolKind::ALL[i].name();
                sink.emit(i, format!("{:<10} done: {:.2}%", name, row.0.best_accuracy));
                Ok(row)
            })
        });
        for line in progress {
            println!("{line}");
        }
        worker.join().expect("compare fan-out panicked")
    })?;

    let mut table = ResultTable::new(format!("{} (R={rounds})", dataset.name()));
    for (p, (result, std)) in ProtocolKind::ALL.iter().zip(&rows) {
        table.add(p.name(), result, *std);
    }
    println!("\n{}", table.render());
    println!(
        "compared {} protocols x {} seed(s), thread budget {} ({} concurrent protocols x {} threads each), in {:.1}s",
        cfgs.len(),
        seed_list.len(),
        budget,
        outer,
        per_protocol,
        t0.elapsed().as_secs_f64()
    );
    Ok(())
}

fn cmd_info(rt: &Runtime) -> Result<()> {
    let m = &rt.manifest;
    println!("platform: {}", rt.platform());
    println!(
        "backbone: conv{:?} fc1={} batch={} img={}",
        m.conv_channels, m.fc1, m.batch, m.img
    );
    println!("artifacts: {}", m.artifacts.len());
    for (tag, c) in &m.configs {
        println!(
            "  {tag}: k={} classes={} act={:?} client/server params {}/{}",
            c.k, c.num_classes, c.act_shape, c.client_params, c.server_params
        );
    }
    Ok(())
}
